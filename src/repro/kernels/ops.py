"""Public jit'd entry points for the SEARS compute kernels.

Dispatch policy: on TPU backends the Pallas kernels run compiled
(``interpret=False``); everywhere else (this CPU container, tests) they run
in interpret mode, which executes the same kernel body in Python for
correctness.  ``impl='ref'`` selects the pure-jnp oracle -- useful both for
differential testing and as an XLA-fusible fallback.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hashing
from repro.kernels import flash_attn, gear_cdc, gf_matmul, ref, sha1


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


# ---------------------------------------------------------------- GF matmul
def rs_apply(M: np.ndarray, data, impl: str = "kernel") -> jnp.ndarray:
    """Apply an (r,k) GF(256) coding matrix to (B, k, L) uint8 pieces.

    RS encode: M = generator_matrix(n, k)  -> (B, n, L) code pieces.
    RS decode: M = decode_matrix(n, k, received_idx) -> (B, k, L) data.
    """
    if impl == "ref":
        return ref.gf_matmul_ref(jnp.asarray(M, jnp.uint8), data)
    return gf_matmul.gf_matmul(M, data, interpret=not _on_tpu())


def rs_encode(code, data, impl: str = "kernel") -> jnp.ndarray:
    """Batched RS encode: (B, k, L) -> (B, n, L) using ``RSCode`` params."""
    from repro.core.rs_code import generator_matrix
    return rs_apply(generator_matrix(code.n, code.k), data, impl=impl)


def rs_decode(code, pieces, indices, impl: str = "kernel") -> jnp.ndarray:
    """Batched RS decode: (B, k, L) received pieces (+ their indices)."""
    from repro.core.rs_code import decode_matrix
    M = decode_matrix(code.n, code.k, tuple(int(i) for i in indices))
    return rs_apply(M, pieces, impl=impl)


# -------------------------------------------- bucketed blob dispatch ------
# Contract: blobs are raw ``bytes``; each is laid out (k, L) uint8 with
# L = code.piece_len(len(blob)) (``rs_code.pack_blob``).  Blobs are
# bucketed by L rounded up to the kernel's TILE_L so one pallas_call
# serves a whole bucket; the batch axis is padded to the next power of
# two to bound the set of compiled (B, k, L) shapes.  Zero pad columns /
# rows are exact under GF(256) (coding is per byte column), so sliced
# results are byte-identical to per-blob host encoding.  The bucketing
# itself lives in ``rs_code.batch_{encode,decode}_blobs``; here we only
# supply the kernel apply_fn and the TPU-shaped padding policy.

def _pow2(n: int) -> int:
    return 1 << max(0, n - 1).bit_length() if n > 1 else 1


def rs_encode_blobs(code, blobs: list[bytes],
                    impl: str = "kernel") -> list[list[bytes]]:
    """Batched RS encode of variable-length blobs -> n pieces per blob."""
    from repro.core import rs_code
    from repro.kernels.gf_matmul import TILE_L
    return rs_code.batch_encode_blobs(
        code, blobs, lambda M, arr: rs_apply(M, arr, impl=impl),
        quantum=TILE_L, pad_batch=_pow2)


def rs_decode_blobs(code, jobs: list[tuple[dict[int, bytes], int]],
                    impl: str = "kernel") -> list[bytes]:
    """Batched RS decode; jobs are (piece_map, original_nbytes) pairs.

    Jobs sharing a received-index set and padded length decode in one
    launch (one decode matrix per bucket); systematic arrivals take the
    host-side memcpy fast path.
    """
    from repro.core import rs_code
    from repro.kernels.gf_matmul import TILE_L
    return rs_code.batch_decode_blobs(
        code, jobs, lambda M, arr: rs_apply(M, arr, impl=impl),
        quantum=TILE_L, pad_batch=_pow2)


# ------------------------------------------------------------------ gear ---
def gear_hash(data, impl: str = "kernel") -> jnp.ndarray:
    """(N,) uint8 -> (N,) uint32 CDC rolling hash."""
    if impl == "ref":
        return ref.gear_hash_ref(jnp.asarray(data, jnp.uint8))
    return gear_cdc.gear_hash(data, interpret=not _on_tpu())


# ----------------------------------------------------------- attention ----
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    scale=None):
    """Fused GQA flash attention (Pallas; VMEM-resident running softmax).

    Beyond-paper perf kernel for the attention-bound prefill cells: the
    pure-JAX blockwise path round-trips (m, l, acc) through HBM per KV
    block; this keeps them in VMEM scratch and skips fully-masked causal
    blocks.  q: (B,S,H,hd); k,v: (B,T,KV,hd).
    """
    return flash_attn.flash_attention(q, k, v, causal=causal,
                                      window=window, scale=scale,
                                      interpret=not _on_tpu())


# ------------------------------------------------------------------ sha1 ---
def sha1_digests(chunks: list[bytes], impl: str = "kernel") -> list[bytes]:
    """Batched SHA-1 of byte chunks -> 20-byte digests (device hot path)."""
    if not chunks:
        return []
    blocks, counts = hashing.sha1_pad_batch(chunks)
    if impl == "ref":
        words = ref.sha1_ref(blocks, counts)
    else:
        words = sha1.sha1_digest_words(blocks, counts,
                                       interpret=not _on_tpu())
    return hashing.digest_words_to_bytes(np.asarray(words))


def sha1_digest_words(blocks, counts, impl: str = "kernel") -> jnp.ndarray:
    if impl == "ref":
        return ref.sha1_ref(blocks, counts)
    return sha1.sha1_digest_words(blocks, counts, interpret=not _on_tpu())
