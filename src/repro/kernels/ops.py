"""Public jit'd entry points for the SEARS compute kernels.

Dispatch policy: on TPU backends the Pallas kernels run compiled
(``interpret=False``); everywhere else (this CPU container, tests) they run
in interpret mode, which executes the same kernel body in Python for
correctness.  ``impl='ref'`` selects the pure-jnp oracle -- useful both for
differential testing and as an XLA-fusible fallback (and the default data
plane off-TPU, where interpret mode is Python-slow; see
``engine.KernelEngine``).

Every entry point here is launch-cached: the jitted callables are module
level (so XLA's compile cache keys on shape alone, never on call site) and
host-side matrix conversions -- generator/decode matrices to device arrays
or GF(2) bit-planes -- are memoized by matrix content instead of being
redone per call.  ``LAUNCHES`` counts data-plane dispatches so batching
layers (``core.scheduler``, benchmarks) can prove launch amortization.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hashing
from repro.kernels import flash_attn, gear_cdc, gf_matmul, ref, sha1


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


# ------------------------------------------------------- launch counting ---
# re-exported for existing callers; the counters themselves live in a
# dependency-free module so readers need not import jax
from repro.kernels.launches import (LAUNCHES, TRACES,  # noqa: E402,F401
                                    LaunchCounter)


# ---------------------------------------------------------------- GF matmul
@functools.lru_cache(maxsize=None)
def _device_matrix(mbytes: bytes, r: int, k: int) -> jnp.ndarray:
    """Device-resident (r,k) uint8 coding matrix, memoized by content."""
    return jnp.asarray(
        np.frombuffer(mbytes, dtype=np.uint8).reshape(r, k))


_gf_ref_jit = jax.jit(ref.gf_matmul_ref)


def rs_apply(M: np.ndarray, data, impl: str = "kernel") -> jnp.ndarray:
    """Apply an (r,k) GF(256) coding matrix to (B, k, L) uint8 pieces.

    RS encode: M = generator_matrix(n, k)  -> (B, n, L) code pieces.
    RS decode: M = decode_matrix(n, k, received_idx) -> (B, k, L) data.
    """
    LAUNCHES.gf += 1
    if impl == "ref":
        M = np.ascontiguousarray(np.asarray(M, dtype=np.uint8))
        Mdev = _device_matrix(M.tobytes(), *M.shape)
        return _gf_ref_jit(Mdev, jnp.asarray(data, jnp.uint8))
    return gf_matmul.gf_matmul(M, data, interpret=not _on_tpu())


def rs_encode(code, data, impl: str = "kernel") -> jnp.ndarray:
    """Batched RS encode: (B, k, L) -> (B, n, L) using ``RSCode`` params."""
    from repro.core.rs_code import generator_matrix
    return rs_apply(generator_matrix(code.n, code.k), data, impl=impl)


def rs_decode(code, pieces, indices, impl: str = "kernel") -> jnp.ndarray:
    """Batched RS decode: (B, k, L) received pieces (+ their indices)."""
    from repro.core.rs_code import decode_matrix
    M = decode_matrix(code.n, code.k, tuple(int(i) for i in indices))
    return rs_apply(M, pieces, impl=impl)


# -------------------------------------------- bucketed blob dispatch ------
# Contract: blobs are raw ``bytes``; each is laid out (k, L) uint8 with
# L = code.piece_len(len(blob)) (``rs_code.pack_blob``).  Blobs are
# bucketed by L rounded up to the kernel's TILE_L so one pallas_call
# serves a whole bucket; the batch axis is padded to the next power of
# two to bound the set of compiled (B, k, L) shapes.  Zero pad columns /
# rows are exact under GF(256) (coding is per byte column), so sliced
# results are byte-identical to per-blob host encoding.  The bucketing
# itself lives in ``rs_code.batch_{encode,decode}_blobs``; here we only
# supply the kernel apply_fn and the TPU-shaped padding policy.

def _pow2(n: int) -> int:
    return 1 << max(0, n - 1).bit_length() if n > 1 else 1


def rs_encode_blobs(code, blobs: list[bytes],
                    impl: str = "kernel") -> list[list[bytes]]:
    """Batched RS encode of variable-length blobs -> n pieces per blob."""
    from repro.core import rs_code
    from repro.kernels.gf_matmul import TILE_L
    return rs_code.batch_encode_blobs(
        code, blobs, lambda M, arr: rs_apply(M, arr, impl=impl),
        quantum=TILE_L, pad_batch=_pow2)


def rs_decode_blobs(code, jobs: list[tuple[dict[int, bytes], int]],
                    impl: str = "kernel") -> list[bytes]:
    """Batched RS decode; jobs are (piece_map, original_nbytes) pairs.

    Jobs sharing a received-index set and padded length decode in one
    launch (one decode matrix per bucket); systematic arrivals take the
    host-side memcpy fast path.
    """
    from repro.core import rs_code
    from repro.kernels.gf_matmul import TILE_L
    return rs_code.batch_decode_blobs(
        code, jobs, lambda M, arr: rs_apply(M, arr, impl=impl),
        quantum=TILE_L, pad_batch=_pow2)


# ------------------------------------------------------------------ gear ---
@jax.jit
def _gear_ref_padded(data: jnp.ndarray) -> jnp.ndarray:
    """Jit-cached gear oracle; compiles once per bucketed stream length."""
    TRACES.gear += 1  # trace-time only: one increment per compiled shape
    return ref.gear_hash_ref(data)


def gear_hash(data, impl: str = "kernel") -> jnp.ndarray:
    """(N,) uint8 -> (N,) uint32 CDC rolling hash (device-resident result).

    The input is zero-padded to ``gear_cdc.bucket_len`` so varying
    lengths reuse a bounded set of compiled launches (pad positions only
    affect hashes at offsets >= N, which are sliced off -- the gear
    window looks strictly backward).  Counted in ``LAUNCHES.gear``.
    """
    data = np.asarray(data, np.uint8)
    n = data.shape[0]
    if n == 0:
        return jnp.zeros((0,), jnp.uint32)
    LAUNCHES.gear += 1
    if impl == "ref":
        return _gear_ref_padded(gear_cdc.pad_to_bucket(data))[:n]
    return gear_cdc.gear_hash(data, interpret=not _on_tpu())


def gear_hash_stream(data, impl: str = "kernel") -> np.ndarray:
    """One gear launch over a whole ingest stream -> host (N,) uint32."""
    data = np.asarray(data, np.uint8)
    if data.shape[0] == 0:
        return np.zeros((0,), np.uint32)
    return np.asarray(gear_hash(data, impl=impl))


@jax.jit
def _gear_fire_ref(data: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    """Jit-cached fused gear hash + boundary mask test -> (N,) bool."""
    TRACES.gear += 1  # trace-time only: one increment per compiled shape
    return (ref.gear_hash_ref(data) & mask) == 0


def gear_candidate_positions(data, mask, impl: str = "kernel") -> np.ndarray:
    """One gear launch over an ingest stream -> sorted candidate positions.

    The device twin of ``chunking.gear_candidates_np``: the 32-tap hash
    and the boundary mask test run on the device (one bucketed launch,
    bool fire bitmap shipped back instead of the 4-byte-per-position hash
    array); the sparse ``flatnonzero`` compaction stays on the host.
    """
    data = np.asarray(data, np.uint8)
    n = data.shape[0]
    if n == 0:
        return np.zeros(0, np.int64)
    LAUNCHES.gear += 1
    mask = jnp.uint32(np.uint32(mask))
    if impl == "ref":
        fire = np.asarray(_gear_fire_ref(gear_cdc.pad_to_bucket(data),
                                         mask))[:n]
    else:
        h = gear_cdc.gear_hash(data, interpret=not _on_tpu())
        fire = np.asarray((h & mask) == 0)
    return np.flatnonzero(fire).astype(np.int64)


# ----------------------------------------------------------- attention ----
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    scale=None):
    """Fused GQA flash attention (Pallas; VMEM-resident running softmax).

    Beyond-paper perf kernel for the attention-bound prefill cells: the
    pure-JAX blockwise path round-trips (m, l, acc) through HBM per KV
    block; this keeps them in VMEM scratch and skips fully-masked causal
    blocks.  q: (B,S,H,hd); k,v: (B,T,KV,hd).
    """
    return flash_attn.flash_attention(q, k, v, causal=causal,
                                      window=window, scale=scale,
                                      interpret=not _on_tpu())


# ------------------------------------------------------------------ sha1 ---
@jax.jit
def _sha1_ref_loop(blocks: jnp.ndarray, counts: jnp.ndarray) -> jnp.ndarray:
    """Jit-cached SHA-1 oracle: ``fori_loop`` over blocks, not unrolled.

    Semantically identical to ``ref.sha1_ref`` but traces the 80-round
    compression once regardless of the padded block count, so the fixed
    (hash_batch, M, 16) engine launch compiles in O(1) and is reused for
    every subsequent batch.
    """
    B, M, _ = blocks.shape
    h0 = jnp.broadcast_to(jnp.asarray(hashing.SHA1_H0.astype(np.int64),
                                      jnp.uint32), (B, 5))

    def body(m, h):
        upd = ref._sha1_block(h, blocks[:, m, :])
        return jnp.where((m < counts)[:, None], upd, h)

    return jax.lax.fori_loop(0, M, body, h0)


def sha1_digests(chunks: list[bytes], impl: str = "kernel") -> list[bytes]:
    """Batched SHA-1 of byte chunks -> 20-byte digests (device hot path)."""
    if not chunks:
        return []
    blocks, counts = hashing.sha1_pad_batch(chunks)
    words = sha1_digest_words(blocks, counts, impl=impl)
    return hashing.digest_words_to_bytes(np.asarray(words))


def sha1_digest_words(blocks, counts, impl: str = "kernel") -> jnp.ndarray:
    LAUNCHES.sha1 += 1
    if impl == "ref":
        return _sha1_ref_loop(jnp.asarray(blocks, jnp.uint32),
                              jnp.asarray(counts, jnp.int32).reshape(-1))
    return sha1.sha1_digest_words(blocks, counts, interpret=not _on_tpu())
