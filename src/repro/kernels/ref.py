"""Pure-jnp oracles for every Pallas kernel in this package.

These are the semantics contracts: each kernel's test sweeps shapes/dtypes
and asserts allclose (exact equality -- all kernels are integer) against
these functions, which in turn are validated against independent host
references (python GF tables, byte-at-a-time gear hash, hashlib SHA-1).
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import gf256
from repro.core.chunking import GEAR_TABLE, WINDOW
from repro.core.hashing import SHA1_H0, SHA1_K

# ---------------------------------------------------------------------------
# GF(256) matmul (Reed-Solomon encode/decode)
# ---------------------------------------------------------------------------

_GF_LOG = jnp.asarray(gf256.GF_LOG, dtype=jnp.int32)
_GF_EXP = jnp.asarray(gf256.GF_EXP, dtype=jnp.int32)


def gf_matmul_ref(M: jnp.ndarray, D: jnp.ndarray) -> jnp.ndarray:
    """GF(256) matrix product via log/exp tables.

    M: (r, k) uint8/int32 coding matrix.
    D: (..., k, L) uint8 data pieces.
    returns (..., r, L) uint8.
    """
    M = jnp.asarray(M, jnp.int32)
    D = jnp.asarray(D, jnp.int32)
    r, k = M.shape
    out = jnp.zeros(D.shape[:-2] + (r, D.shape[-1]), dtype=jnp.int32)
    for j in range(k):
        m = M[:, j].reshape((1,) * (D.ndim - 2) + (r, 1))
        d = D[..., j : j + 1, :]
        prod = _GF_EXP[_GF_LOG[m] + _GF_LOG[d]]
        prod = jnp.where((m == 0) | (d == 0), 0, prod)
        out = out ^ prod
    return out.astype(jnp.uint8)


# ---------------------------------------------------------------------------
# Gear CDC rolling hash
# ---------------------------------------------------------------------------

_GEAR = jnp.asarray(GEAR_TABLE.astype(np.int64), dtype=jnp.uint32)


def gear_hash_ref(data: jnp.ndarray) -> jnp.ndarray:
    """(N,) uint8 -> (N,) uint32 windowed gear hash (32-tap weighted sum)."""
    data = jnp.asarray(data, jnp.int32)
    g = _GEAR[data]
    n = g.shape[0]
    h = jnp.zeros_like(g)
    for j in range(min(WINDOW, n)):
        shifted = jnp.pad(g[: n - j], (j, 0)) << jnp.uint32(j)
        h = h + shifted
    return h


# ---------------------------------------------------------------------------
# SHA-1 (batched, padded-block input)
# ---------------------------------------------------------------------------

_H0 = jnp.asarray(SHA1_H0.astype(np.int64), dtype=jnp.uint32)
_K = jnp.asarray(SHA1_K.astype(np.int64), dtype=jnp.uint32)


def _rotl(x: jnp.ndarray, c: int) -> jnp.ndarray:
    return (x << jnp.uint32(c)) | (x >> jnp.uint32(32 - c))


def _sha1_block(h: jnp.ndarray, words: jnp.ndarray) -> jnp.ndarray:
    """One SHA-1 compression. h: (..., 5) uint32, words: (..., 16) uint32."""
    w = [words[..., t] for t in range(16)]
    for t in range(16, 80):
        w.append(_rotl(w[t - 3] ^ w[t - 8] ^ w[t - 14] ^ w[t - 16], 1))
    a, b, c, d, e = (h[..., i] for i in range(5))
    for t in range(80):
        if t < 20:
            f, k = (b & c) | (~b & d), _K[0]
        elif t < 40:
            f, k = b ^ c ^ d, _K[1]
        elif t < 60:
            f, k = (b & c) | (b & d) | (c & d), _K[2]
        else:
            f, k = b ^ c ^ d, _K[3]
        tmp = _rotl(a, 5) + f + e + k + w[t]
        e, d, c, b, a = d, c, _rotl(b, 30), a, tmp
    return h + jnp.stack([a, b, c, d, e], axis=-1)


def sha1_ref(blocks: jnp.ndarray, counts: jnp.ndarray) -> jnp.ndarray:
    """Batched SHA-1 over padded message blocks.

    blocks: (B, M, 16) uint32 big-endian words (from sha1_pad_batch).
    counts: (B,) int32 number of real blocks per message.
    returns (B, 5) uint32 digest words.
    """
    blocks = jnp.asarray(blocks, jnp.uint32)
    counts = jnp.asarray(counts, jnp.int32)
    B, M, _ = blocks.shape
    h = jnp.broadcast_to(_H0, (B, 5)).astype(jnp.uint32)
    for m in range(M):
        upd = _sha1_block(h, blocks[:, m, :])
        h = jnp.where((m < counts)[:, None], upd, h)
    return h
