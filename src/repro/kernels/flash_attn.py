"""Flash attention Pallas kernel (GQA, causal/sliding-window).

The 32k-prefill roofline cells are attention-bound and the pure-JAX
blockwise path (models/layers.py) round-trips its running max/sum/acc
through HBM every KV block.  This kernel keeps them in VMEM scratch:

  grid = (B, H, n_q_blocks, n_kv_blocks)   -- TPU iterates the minor-most
  axis sequentially on-core, so the (m, l, acc) scratch carries across KV
  blocks of one (batch, head, q-block) cell; the output tile is written
  once on the last KV block.

GQA is handled in the k/v BlockSpec index maps (q head h reads kv head
h // group_size).  Causal + sliding-window masking is computed from
global block offsets, and fully-masked KV blocks are skipped via
``pl.when`` (the causal-skip optimization: ~2x fewer score FLOPs).

Layouts: q/out (B, H, S, hd); k/v (B, KV, T, hd) -- ``ops.flash_attention``
transposes from the model's (B, S, H, hd) convention and pads S/T to
block multiples (padded KV masked by position).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BQ = 256
BK = 256
NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale: float, causal: bool, window: int, t_real: int,
            n_kv: int, q_offset: int):
    ik = pl.program_id(3)
    iq = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # queries occupy the LAST S positions of the T-long KV axis
    q_idx = q_offset + iq * BQ \
        + jax.lax.broadcasted_iota(jnp.int32, (BQ, BK), 0)
    k_idx = ik * BK + jax.lax.broadcasted_iota(jnp.int32, (BQ, BK), 1)

    # causal skip: a KV block strictly in the future contributes nothing
    block_live = True
    if causal:
        block_live = (ik * BK) <= (q_offset + iq * BQ + BQ - 1)

    @pl.when(block_live)
    def _step():
        q = q_ref[0, 0].astype(jnp.float32)  # (BQ, hd)
        k = k_ref[0, 0].astype(jnp.float32)  # (BK, hd)
        v = v_ref[0, 0]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # (BQ, BK)
        mask = k_idx < t_real  # padded tail of KV
        if causal:
            mask &= k_idx <= q_idx
        if window > 0:
            mask &= k_idx > (q_idx - window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ik == n_kv - 1)
    def _finish():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / denom[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "scale", "causal", "window", "t_real", "q_offset", "interpret"))
def _flash_padded(q, k, v, *, scale, causal, window, t_real, q_offset,
                  interpret=True):
    B, H, S, hd = q.shape
    KV, T = k.shape[1], k.shape[2]
    G = H // KV
    nq, nk = S // BQ, T // BK
    grid = (B, H, nq, nk)
    kernel = functools.partial(_kernel, scale=scale, causal=causal,
                               window=window, t_real=t_real, n_kv=nk,
                               q_offset=q_offset)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, BQ, hd), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, BK, hd),
                         lambda b, h, iq, ik, G=G: (b, h // G, ik, 0)),
            pl.BlockSpec((1, 1, BK, hd),
                         lambda b, h, iq, ik, G=G: (b, h // G, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, BQ, hd),
                               lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, S, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((BQ,), jnp.float32),   # running max m
            pltpu.VMEM((BQ,), jnp.float32),   # running denom l
            pltpu.VMEM((BQ, hd), jnp.float32),  # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    scale: float | None = None,
                    interpret: bool = True) -> jnp.ndarray:
    """Model-layout entry point.

    q: (B, S, H, hd); k, v: (B, T, KV, hd).  Returns (B, S, H, hd).
    """
    B, S, H, hd = q.shape
    T, KV = k.shape[1], k.shape[2]
    scale = scale or (1.0 / np.sqrt(hd))
    qt = jnp.moveaxis(q, 2, 1)  # (B, H, S, hd)
    kt = jnp.moveaxis(k, 2, 1)
    vt = jnp.moveaxis(v, 2, 1)
    ps, pt = (-S) % BQ, (-T) % BK
    if ps:
        qt = jnp.pad(qt, ((0, 0), (0, 0), (0, ps), (0, 0)))
    if pt:
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, pt), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, pt), (0, 0)))
    out = _flash_padded(qt, kt, vt, scale=scale, causal=causal,
                        window=window, t_real=T, q_offset=T - S,
                        interpret=interpret)
    return jnp.moveaxis(out[:, :, :S], 1, 2)
