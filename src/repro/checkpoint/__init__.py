"""SEARS-backed checkpointing: dedup + erasure-coded, k-of-n restore."""

from repro.checkpoint.manager import SEARSCheckpointManager

__all__ = ["SEARSCheckpointManager"]
