"""Pytree <-> bytes serialization with a mesh-independent manifest.

Each leaf serializes to raw little-endian bytes plus a manifest record
(path, dtype, global shape).  Restore rebuilds the host array and
``jax.device_put``s it onto *any* target sharding -- the checkpoint format
never encodes the mesh, which is what makes elastic restore (write on one
mesh shape, resume on another) a no-op.
"""

from __future__ import annotations

import json

import jax
import jax.numpy as jnp
import numpy as np

_SEP = "/"


def _path_str(path) -> str:
    keys = []
    for k in path:
        keys.append(str(getattr(k, "key", getattr(k, "idx", k))))
    return _SEP.join(keys)


def serialize(pytree) -> tuple[str, dict[str, bytes]]:
    """Returns (manifest_json, {leaf_path: raw_bytes})."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(pytree)
    records = []
    blobs: dict[str, bytes] = {}
    for path, leaf in flat:
        name = _path_str(path)
        arr = np.asarray(jax.device_get(leaf))
        if arr.dtype == jnp.bfloat16:
            payload = arr.view(np.uint16).tobytes()
            dtype = "bfloat16"
        else:
            payload = arr.tobytes()
            dtype = arr.dtype.name
        records.append({"path": name, "dtype": dtype,
                        "shape": list(arr.shape)})
        blobs[name] = payload
    manifest = json.dumps({"treedef": str(treedef), "leaves": records})
    return manifest, blobs


def deserialize(manifest_json: str, blobs: dict[str, bytes], like,
                shardings=None):
    """Rebuild a pytree with the structure of ``like``.

    ``like``: pytree of arrays or ShapeDtypeStructs providing the treedef.
    ``shardings``: optional matching pytree of NamedShardings -- leaves are
    device_put onto them (elastic restore path).
    """
    flat_like, treedef = jax.tree_util.tree_flatten_with_path(like)
    records = {r["path"]: r for r in json.loads(manifest_json)["leaves"]}
    shard_flat = (jax.tree_util.tree_flatten(shardings)[0]
                  if shardings is not None else [None] * len(flat_like))
    out = []
    for (path, leaf), sh in zip(flat_like, shard_flat):
        name = _path_str(path)
        rec = records[name]
        raw = blobs[name]
        if rec["dtype"] == "bfloat16":
            arr = np.frombuffer(raw, np.uint16).reshape(rec["shape"])
            arr = jnp.asarray(arr).view(jnp.bfloat16)
        else:
            arr = np.frombuffer(raw, np.dtype(rec["dtype"])).reshape(
                rec["shape"])
            arr = jnp.asarray(arr)
        if sh is not None:
            arr = jax.device_put(arr, sh)
        out.append(arr)
    return jax.tree_util.tree_unflatten(treedef, out)
