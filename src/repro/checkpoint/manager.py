"""SEARS-backed checkpoint manager -- the paper's system as the training
cluster's storage substrate (DESIGN.md S2).

Per save: every leaf of (params, opt_state, data-state) becomes one SEARS
file ``ckpt/<run>/<step>/<leaf-path>``.  The SEARS pipeline then gives,
for free:

* **dedup across steps/experiments** -- frozen layers, embeddings shared
  between runs, and any unchanged optimizer leaves are stored once
  (content-defined chunking finds unchanged spans even inside partially
  changed leaves);
* **(n,k) erasure-coded pieces** -- any n-k storage nodes can die between
  save and restore with zero data loss, without 2x-3x replication cost;
* **k-of-n restore reads** -- restore latency is the k-th order statistic,
  not the max: storage stragglers do not stall a 1000-node cluster's
  restart (ULB binding keeps one cluster per run for exactly this);
* **elastic restore** -- the manifest stores global shapes only, so a
  checkpoint written on one mesh restores onto any other.

``save_async`` offloads the serialize+upload to a background thread so the
training loop only blocks on the device->host copy.
"""

from __future__ import annotations

import threading

import jax

from repro.checkpoint import serializer
from repro.core.store import SEARSStore


class CheckpointError(RuntimeError):
    pass


class SEARSCheckpointManager:
    def __init__(self, store: SEARSStore | None = None, run: str = "run0",
                 user: str = "trainer", keep_last: int = 3, **store_kw):
        store_kw.setdefault("binding", "ulb")  # fast-restart read path
        store_kw.setdefault("num_clusters", 4)
        self.store = store or SEARSStore(**store_kw)
        self.run = run
        self.user = user
        self.keep_last = keep_last
        self._steps: list[int] = []
        self._lock = threading.Lock()
        self._pending: threading.Thread | None = None

    # ------------------------------------------------------------------
    def _fname(self, step: int, leaf: str) -> str:
        return f"ckpt/{self.run}/{step:08d}/{leaf}"

    def _manifest_name(self, step: int) -> str:
        return self._fname(step, "MANIFEST.json")

    def save(self, step: int, pytree, timestamp: float = 0.0) -> dict:
        """Synchronous save. Returns upload stats summary."""
        manifest, blobs = serializer.serialize(pytree)
        with self._lock:
            total_up = 0
            total_bytes = 0
            for name, blob in blobs.items():
                st = self.store.put_file(self.user, self._fname(step, name),
                                         blob, timestamp=timestamp)
                total_up += st.bytes_uploaded
                total_bytes += st.file_bytes
            self.store.put_file(self.user, self._manifest_name(step),
                                manifest.encode(), timestamp=timestamp)
            self._steps.append(step)
            self._gc()
        return {"step": step, "bytes": total_bytes,
                "bytes_after_dedup": total_up,
                "dedup_saving": 1.0 - total_up / max(1, total_bytes)}

    def save_async(self, step: int, pytree, timestamp: float = 0.0):
        """Device->host copy now; chunk/hash/encode/upload in background."""
        host_tree = jax.tree.map(jax.device_get, pytree)
        self.wait()
        t = threading.Thread(target=self.save,
                             args=(step, host_tree, timestamp), daemon=True)
        t.start()
        self._pending = t

    def wait(self):
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def _gc(self):
        while len(self._steps) > self.keep_last:
            old = self._steps.pop(0)
            for fname in list(self.store.switching[self.user].table):
                if fname.startswith(f"ckpt/{self.run}/{old:08d}/"):
                    self.store.delete_file(self.user, fname)

    # ------------------------------------------------------------------
    def steps(self) -> list[int]:
        return sorted(self._steps)

    def latest_step(self) -> int | None:
        return max(self._steps) if self._steps else None

    def restore(self, like, step: int | None = None, shardings=None):
        """Restore the checkpoint at ``step`` (default: latest complete).

        ``like``: pytree of arrays/ShapeDtypeStructs giving the structure;
        ``shardings``: optional target shardings (elastic restore).
        Raises CheckpointError if more than n-k pieces of any chunk are
        gone.
        """
        self.wait()
        step = step if step is not None else self.latest_step()
        if step is None:
            raise CheckpointError("no checkpoints saved")
        with self._lock:
            try:
                manifest_blob, _ = self.store.get_file(
                    self.user, self._manifest_name(step))
            except ValueError as e:  # < k pieces survive
                raise CheckpointError(
                    f"checkpoint manifest unrecoverable: {e}") from e
            blobs: dict[str, bytes] = {}
            flat, _ = jax.tree_util.tree_flatten_with_path(like)
            restore_stats = []
            for path, _leaf in flat:
                name = serializer._path_str(path)
                try:
                    blob, st = self.store.get_file(
                        self.user, self._fname(step, name))
                except ValueError as e:  # < k pieces survive
                    raise CheckpointError(
                        f"checkpoint leaf {name} unrecoverable: {e}") from e
                blobs[name] = blob
                restore_stats.append(st)
        tree = serializer.deserialize(manifest_blob.decode(), blobs, like,
                                      shardings=shardings)
        self.last_restore_time = sum(s.time_s for s in restore_stats)
        return tree
