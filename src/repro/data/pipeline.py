"""Training data pipeline.

Two sources, one interface (`batches(step) -> dict of host arrays`):

* ``SyntheticCorpus`` -- deterministic structured token streams (zipf
  unigram mixture with per-document topic drift), seeded by (seed, step)
  so every host generates its own shard without coordination and restart
  at step k reproduces the exact stream (checkpoint/restart determinism).
* ``ByteCorpus`` -- byte-level tokenization of real files with document
  packing and EOS separators; used by the examples to train on source
  trees and by the SEARS integration tests (the corpus doubles as dedup
  workload).

Batches are *global*; ``host_slice`` carves this host's rows for
multi-host running (jax.process_index-based, data-parallel outermost).
"""

from __future__ import annotations

import dataclasses
import os

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seq_len: int
    global_batch: int
    vocab_size: int
    seed: int = 0


class SyntheticCorpus:
    """Deterministic synthetic LM stream (restart-reproducible)."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        base = np.random.default_rng(cfg.seed)
        v = cfg.vocab_size
        # zipf-ish unigram table, fixed per corpus
        ranks = np.arange(1, v + 1, dtype=np.float64)
        self._probs = (1.0 / ranks ** 1.1)
        self._probs /= self._probs.sum()
        self._topic_shift = base.integers(0, v, size=64)

    def batch(self, step: int) -> dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed, step))
        B, S, V = cfg.global_batch, cfg.seq_len, cfg.vocab_size
        toks = rng.choice(V, size=(B, S), p=self._probs)
        topic = self._topic_shift[rng.integers(0, 64, size=(B, 1))]
        toks = (toks + topic) % V
        return {"tokens": toks.astype(np.int32)}


class ByteCorpus:
    """Byte-level tokens from files, packed into fixed-length rows."""

    EOS = 0

    def __init__(self, cfg: DataConfig, paths: list[str]):
        self.cfg = cfg
        parts = []
        for p in sorted(paths):
            if os.path.isfile(p):
                with open(p, "rb") as f:
                    raw = np.frombuffer(f.read(), dtype=np.uint8)
                # byte tokens shifted +1 so EOS=0 is unambiguous
                parts.append(raw.astype(np.int32) + 1)
                parts.append(np.array([self.EOS], np.int32))
        if not parts:
            raise ValueError("empty corpus")
        self._tokens = np.concatenate(parts)

    def batch(self, step: int) -> dict[str, np.ndarray]:
        cfg = self.cfg
        B, S = cfg.global_batch, cfg.seq_len
        n = self._tokens.shape[0]
        rng = np.random.default_rng((cfg.seed, step))
        starts = rng.integers(0, max(1, n - S - 1), size=B)
        rows = np.stack([np.resize(self._tokens[s:s + S], S) for s in starts])
        return {"tokens": np.minimum(rows, cfg.vocab_size - 1).astype(np.int32)}


def host_slice(batch: dict[str, np.ndarray], process_index: int,
               process_count: int) -> dict[str, np.ndarray]:
    """This host's rows of the global batch (data-parallel outermost)."""
    def sl(x):
        B = x.shape[0]
        per = B // process_count
        return x[process_index * per:(process_index + 1) * per]
    return {k: sl(v) for k, v in batch.items()}
