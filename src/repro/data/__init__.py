"""Data pipeline: tokenized corpora, packing, sharded batches."""
