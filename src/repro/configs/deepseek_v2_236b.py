"""deepseek-v2-236b [moe]: 60L d_model=5120 128H d_ff=1536(expert)
vocab=102400, MLA kv_lora=512, 2 shared + 160 routed experts top-6.
[arXiv:2405.04434; hf]
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b",
    family="moe",
    n_layers=60,
    d_model=5120,
    n_heads=128,
    n_kv_heads=128,
    d_ff=1536,  # per-expert FFN dim (assignment spec)
    vocab_size=102_400,
    # MoE: 160 routed top-6 + 2 shared experts
    n_experts=160,
    experts_per_token=6,
    n_shared_experts=2,
    moe_d_ff=1536,
    moe_group_size=512,
    # MLA
    use_mla=True,
    kv_lora_rank=512,
    q_lora_rank=1536,
    qk_nope_head_dim=128,
    qk_rope_head_dim=64,
    v_head_dim=128,
    rope_theta=10_000.0,
)
