"""gemma3-1b [dense]: 26L d_model=1152 4H (GQA kv=1) d_ff=6912
vocab=262144 -- 5 local(sliding-window):1 global attention, 128k+ context.
[hf:google/gemma-3-1b-pt]
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-1b",
    family="dense",
    n_layers=26,
    d_model=1152,
    n_heads=4,
    n_kv_heads=1,
    head_dim=256,
    d_ff=6912,
    vocab_size=262_144,
    sliding_window=512,
    global_every=6,  # layers 5, 11, 17, 23 are global (5 local : 1 global)
    rope_theta=1_000_000.0,
    tie_embeddings=True,
    # 4 q heads on a 16-way model axis: pad to 16 masked slots (kv=1)
    n_heads_padded=16,
)
