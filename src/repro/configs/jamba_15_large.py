"""jamba-1.5-large-398b [hybrid]: 72L d_model=8192 64H (GQA kv=8)
d_ff=24576 vocab=65536 -- Mamba:attention 7:1 interleave, MoE 16 experts
top-2 on every other layer (-> 398B total / ~94B active).
[arXiv:2403.19887; hf]
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=24_576,
    vocab_size=65_536,
    # hybrid pattern: 1 attention layer per 8 (1:7 attn:mamba)
    attn_every=8,
    ssm_state=16,
    ssm_conv=4,
    d_inner=16_384,
    # MoE every other layer, 16 experts top-2
    n_experts=16,
    experts_per_token=2,
    moe_every=2,
    moe_d_ff=24_576,
    moe_group_size=512,
    scan_group=8,
    use_rope=False,  # jamba uses no positional encoding (mamba provides it)
    # ssm_compute_dtype="bf16" was tried and REFUTED (no traffic change,
    # SSPerf cell 2 iter 4) -- stays fp32
)
