"""falcon-mamba-7b [ssm]: 64L d_model=4096 attention-free, ssm_state=16
vocab=65024 -- mamba1 architecture.
[arXiv:2410.05355]
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="falcon-mamba-7b",
    family="ssm",
    n_layers=64,
    d_model=4096,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,  # attention-free: the mamba block is the whole layer
    vocab_size=65_024,
    ssm_state=16,
    ssm_conv=4,
    d_inner=8192,
    tie_embeddings=True,
)
