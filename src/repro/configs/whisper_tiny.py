"""whisper-tiny [audio]: 4L d_model=384 6H d_ff=1536 vocab=51865 --
encoder-decoder; conv audio frontend is a stub (input_specs provides
precomputed frame embeddings).
[arXiv:2212.04356]
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny",
    family="encdec",
    n_layers=4,  # decoder layers
    n_encoder_layers=4,
    d_model=384,
    n_heads=6,
    n_kv_heads=6,
    d_ff=1536,
    vocab_size=51_865,
    is_encoder_decoder=True,
    act="gelu",
    tie_embeddings=True,
    # 6 MHA heads on a 16-way model axis would replicate all attention
    # compute 16x; pad to 16 so it shards (10 masked slots)
    n_heads_padded=16,
    n_kv_heads_padded=16,
)
