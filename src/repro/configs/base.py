"""Model / shape / run configuration system.

Every assigned architecture is a ``ModelConfig`` in its own module under
``repro.configs`` and is registered by id (``--arch <id>``).  ``reduced()``
derives the same-family small config used by the CPU smoke tests; the full
configs are only ever lowered via ShapeDtypeStructs in the dry-run.
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Literal

Family = Literal["dense", "moe", "ssm", "hybrid", "encdec", "vlm"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Family
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // n_heads

    # --- MoE ---
    n_experts: int = 0
    experts_per_token: int = 0
    n_shared_experts: int = 0
    moe_d_ff: int = 0  # per-expert hidden dim (if different from d_ff)
    moe_every: int = 1  # MoE FFN every k-th layer (jamba: 2)
    capacity_factor: float = 1.25
    moe_group_size: int = 1024  # tokens per dispatch group

    # --- MLA (deepseek-v2) ---
    use_mla: bool = False
    kv_lora_rank: int = 0
    q_lora_rank: int = 0
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128

    # --- attention pattern ---
    sliding_window: int = 0  # 0 = full attention
    global_every: int = 0  # gemma3: 1 global layer per k (5 local : 1 global)
    # TP head padding: store H/KV padded to a mesh-divisible count with
    # zeroed+masked pad slots (Megatron-style) so attention weights shard
    # instead of replicating.  0 = no padding.
    n_heads_padded: int = 0
    n_kv_heads_padded: int = 0

    # --- SSM (mamba1) ---
    ssm_state: int = 0
    ssm_conv: int = 4
    d_inner: int = 0
    dt_rank: int = 0
    attn_every: int = 0  # jamba: 1 attention layer per k (1:7 -> 8)
    # dtype of the within-chunk scan tensors (B,c,Di,N); the cross-chunk
    # carry stays fp32 either way.  bf16 halves the SSM's HBM traffic at
    # a known precision trade (SSPerf cell 2 iteration 4).
    ssm_compute_dtype: str = "fp32"

    # --- encoder-decoder ---
    is_encoder_decoder: bool = False
    n_encoder_layers: int = 0

    # --- multimodal frontend stub ---
    n_patches: int = 0  # image/audio embeddings prepended (input_specs stub)

    # --- misc ---
    norm_eps: float = 1e-6
    rope_theta: float = 10_000.0
    use_rope: bool = True
    tie_embeddings: bool = False
    act: str = "swiglu"  # swiglu | gelu
    scan_group: int = 1  # layers per scan step (pattern period)

    def __post_init__(self):
        if self.head_dim == 0 and self.n_heads:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        if self.n_experts and not self.moe_d_ff:
            object.__setattr__(self, "moe_d_ff", self.d_ff)
        if self.ssm_state and not self.d_inner:
            object.__setattr__(self, "d_inner", 2 * self.d_model)
        if self.ssm_state and not self.dt_rank:
            object.__setattr__(self, "dt_rank", -(-self.d_model // 16))

    # ------------------------------------------------------------------
    @property
    def h_store(self) -> int:
        """Stored (possibly padded) query-head count."""
        return self.n_heads_padded or self.n_heads

    @property
    def kv_store(self) -> int:
        return self.n_kv_heads_padded or self.n_kv_heads

    @property
    def is_subquadratic(self) -> bool:
        """Can this arch serve 500k-token decode? (SSM/hybrid/sliding-window)"""
        return bool(self.ssm_state) or bool(self.sliding_window)

    @property
    def has_decode(self) -> bool:
        return True  # all assigned archs have a decoder

    def param_count(self) -> int:
        """Parameter count from eval_shape (used for MODEL_FLOPS = 6*N*D)."""
        from repro.models import api
        return api.param_count(self)

    def active_param_count(self) -> int:
        from repro.models import api
        return api.active_param_count(self)

    # ------------------------------------------------------------------
    def reduced(self) -> "ModelConfig":
        """Small same-family config for CPU smoke tests."""
        def shrink(v, cap):
            return min(v, cap) if v else v
        period = max(self.scan_group, self.attn_every, self.global_every,
                     self.moe_every, 1)
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            n_layers=max(2, 2 * period),
            n_encoder_layers=2 if self.is_encoder_decoder else 0,
            d_model=128,
            n_heads=max(1, min(self.n_heads, 4)),
            n_kv_heads=max(1, min(self.n_kv_heads, 2)),
            head_dim=32,
            d_ff=shrink(self.d_ff, 256),
            vocab_size=512,
            n_experts=shrink(self.n_experts, 8),
            experts_per_token=shrink(self.experts_per_token, 2),
            moe_d_ff=shrink(self.moe_d_ff, 128),
            moe_group_size=64,
            # no token dropping in smoke tests: keeps grouped prefill
            # dispatch and single-token decode dispatch bit-consistent
            capacity_factor=4.0,
            kv_lora_rank=shrink(self.kv_lora_rank, 32),
            q_lora_rank=shrink(self.q_lora_rank, 32),
            qk_nope_head_dim=32 if self.use_mla else self.qk_nope_head_dim,
            qk_rope_head_dim=16 if self.use_mla else self.qk_rope_head_dim,
            v_head_dim=32 if self.use_mla else self.v_head_dim,
            d_inner=256 if self.ssm_state else 0,
            dt_rank=8 if self.ssm_state else 0,
            sliding_window=shrink(self.sliding_window, 64),
            n_patches=shrink(self.n_patches, 16),
            # keep head padding exercised in smoke tests when present
            n_heads_padded=8 if self.n_heads_padded else 0,
            n_kv_heads_padded=4 if self.n_kv_heads_padded else 0,
        )


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: Literal["train", "prefill", "decode"]


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}

ARCH_IDS = [
    "deepseek_v2_236b",
    "granite_moe_1b",
    "phi3_vision_4b",
    "whisper_tiny",
    "gemma3_1b",
    "deepseek_coder_33b",
    "llama32_1b",
    "internlm2_20b",
    "falcon_mamba_7b",
    "jamba_15_large",
]


def get_config(arch: str) -> ModelConfig:
    arch = arch.replace("-", "_")
    if arch not in ARCH_IDS:
        raise KeyError(f"unknown arch {arch!r}; choose from {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{arch}")
    return mod.CONFIG


def runnable_cells() -> list[tuple[str, str]]:
    """All (arch, shape) dry-run cells, honouring the long_500k skip rule."""
    cells = []
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape in SHAPES.values():
            if shape.name == "long_500k" and not cfg.is_subquadratic:
                continue  # pure full-attention: noted skip (DESIGN.md S5)
            cells.append((arch, shape.name))
    return cells
