PYTHON ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: test test-fast bench bench-pipeline headline

# tier-1 verification command
test:
	$(PYTHON) -m pytest -x -q

# skip the slow model/kernel suites; storage core only
test-fast:
	$(PYTHON) -m pytest -x -q tests/test_store.py tests/test_engine.py \
		tests/test_gf256_rs.py tests/test_chunking_hashing.py \
		tests/test_workload_binding.py tests/test_system.py

# full paper-claim benchmark battery (results/bench.json)
bench:
	$(PYTHON) -m benchmarks.run

# per-chunk vs batched data-plane comparison (BENCH_pipeline.json)
bench-pipeline:
	$(PYTHON) -m benchmarks.run --only pipeline_bench

# headline 3 MB retrieval claim; ENGINE=numpy|kernel
ENGINE ?= numpy
headline:
	$(PYTHON) benchmarks/headline_3mb.py --engine $(ENGINE)
