PYTHON ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: test test-slow test-fast test-launches test-shards test-cache \
	lint bench bench-pipeline bench-smoke bench-repair bench-disaster \
	bench-classes bench-shards bench-slo headline

# tier-1 verification command (slow interpret-mode kernel tests are
# deselected by pytest.ini; run them with `make test-slow`)
test:
	$(PYTHON) -m pytest -x -q

# the slow interpret-mode Pallas kernel sweeps only
test-slow:
	$(PYTHON) -m pytest -x -q -m slow

# dispatch-regression lane (also a CI job): a put window must stay
# O(1) gear + O(1) SHA-1 + O(buckets) GF launches with no gear retraces,
# a storm repair pass must stay O(buckets) per sub-batch, not O(chunks)
# (including whole-cluster re-placement drains and scrub sweeps), and a
# mixed-storage-class window must stay O(code buckets x length buckets),
# never O(files)
test-launches:
	$(PYTHON) -m pytest -x -q tests/test_ingest.py tests/test_repair.py \
		tests/test_classes.py tests/test_disaster.py

# sharded-control-plane lane: ShardMap mechanics + the N-shard-vs-
# 1-shard differential proof harness (all engines, mid-trace add/drain),
# then the core store/scheduler suites re-run sanitized with 3 control
# shards so the per-shard launch model and shard-ledger conservation
# checks run live on every window
test-shards:
	$(PYTHON) -m pytest -x -q tests/test_shards.py
	SEARS_SANITIZE=1 SEARS_SHARDS=3 $(PYTHON) -m pytest -x -q \
		tests/test_store.py tests/test_scheduler.py

# block-cache lane: BlockCache mechanics, write-back ack/drain/delete
# ordering, shard-drain + cluster-loss barriers, scheduler priority
# lanes + admission control, and the cache-on-vs-off differential
# proof -- then the whole suite again with the runtime sanitizer's
# cache-ledger audit live on every window
test-cache:
	$(PYTHON) -m pytest -x -q tests/test_cache.py
	SEARS_SANITIZE=1 $(PYTHON) -m pytest -x -q tests/test_cache.py

# searslint: begin-purity, dispatch hygiene, counter coverage, plan
# determinism, cache discipline (exits 1 on any unwaivered finding)
lint:
	$(PYTHON) -m repro.lint src tests benchmarks

# skip the slow model/kernel suites; storage core only
test-fast:
	$(PYTHON) -m pytest -x -q tests/test_store.py tests/test_engine.py \
		tests/test_scheduler.py tests/test_ingest.py \
		tests/test_repair.py tests/test_classes.py \
		tests/test_disaster.py \
		tests/test_gf256_rs.py tests/test_chunking_hashing.py \
		tests/test_workload_binding.py tests/test_system.py \
		tests/test_lint.py tests/test_sanitizer.py tests/test_shards.py \
		tests/test_cache.py

# full paper-claim benchmark battery (results/bench.json)
bench:
	$(PYTHON) -m benchmarks.run

# per-chunk vs batched data-plane comparison (BENCH_pipeline.json)
bench-pipeline:
	$(PYTHON) -m benchmarks.run --only pipeline_bench

# quick CI smoke: data-plane pipeline + cross-user scheduler + control
# sharding + storm repair + disaster recovery + storage-class + block
# cache/SLO benchmarks (BENCH_pipeline.json + BENCH_scheduler.json +
# BENCH_shard.json + BENCH_repair.json + BENCH_disaster.json +
# BENCH_classes.json + BENCH_slo.json)
bench-smoke:
	$(PYTHON) -m benchmarks.run --only pipeline_bench,scheduler_bench,shard_bench,repair_bench,disaster_bench,class_bench,slo_bench

# failure-storm repair: per-chunk vs batched cross-cluster rebuild on
# both engines (BENCH_repair.json)
bench-repair:
	$(PYTHON) -m benchmarks.run --only repair_bench

# disaster recovery: whole-cluster-loss rebuild throughput, scrub
# overhead, and the repair-throttle SLO gate (BENCH_disaster.json)
bench-disaster:
	$(PYTHON) -m benchmarks.run --only disaster_bench

# storage classes: realtime-vs-archival retrieval/overhead trade-off and
# mixed-window launch economics on both engines (BENCH_classes.json)
bench-classes:
	$(PYTHON) -m benchmarks.run --only class_bench

# block cache & SLO: zipf cache-hit latency, write-back put-ack
# deferral, and the two-class admission-control knee sweep
# (BENCH_slo.json)
bench-slo:
	$(PYTHON) -m benchmarks.run --only slo_bench

# control-plane sharding: 1/2/4-shard flush windows must produce
# byte-identical artifacts at O(buckets)-per-sub-window launch cost
# (BENCH_shard.json)
bench-shards:
	$(PYTHON) -m benchmarks.run --only shard_bench

# headline 3 MB retrieval claim; ENGINE=numpy|kernel
ENGINE ?= numpy
headline:
	$(PYTHON) benchmarks/headline_3mb.py --engine $(ENGINE)
